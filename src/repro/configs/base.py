"""Model configuration for the assigned architectures (+ paper apps).

One frozen dataclass describes every family (dense GQA / MLA, MoE, SSM,
hybrid, audio/vlm backbones).  Each arch file in this package instantiates
the exact published config; ``reduced()`` derives the CPU-smoke-test config
of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")

SHAPE_SPECS = {
    # name            seq_len   global_batch  kind
    "train_4k":    dict(seq=4096,    batch=256, kind="train"),
    "prefill_32k": dict(seq=32768,   batch=32,  kind="prefill"),
    "decode_32k":  dict(seq=32768,   batch=128, kind="decode"),
    "long_500k":   dict(seq=524288,  batch=1,   kind="decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0               # 0 -> d_model // n_heads

    # --- attention ---------------------------------------------------------
    attention: str = "gqa"        # gqa | mla | none
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # MLA (minicpm3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    mlp_type: str = "swiglu"      # swiglu (3-matrix) | gelu (2-matrix,
                                  # gpt-bigcode style: granite-20b)

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0             # per-expert hidden (fine-grained)

    # --- SSM (mamba2 / zamba2) ---------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    ssm_groups: int = 1

    # --- hybrid (zamba2): shared attention block every N ssm layers --------
    hybrid_attn_every: int = 0

    # --- io ----------------------------------------------------------------
    input_mode: str = "tokens"    # tokens | embeddings (modality stub)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    # --- CAM integration (the paper's technique as an LM feature) ----------
    cam_attention: bool = False   # CAM-retrieval attention for decode
    cam_topk: int = 128
    cam_router: bool = False      # CAM best-match MoE routing
    cam_router_bits: int = 0      # quantization bits for CAM routing (0=fp)
    cam_router_std: float = 0.0   # D2D variation std for CAM routing
    cam_attn_bits: int = 0        # MCAM quantization bits for retrieval keys
    cam_chunk: int = 2048         # streaming chunk for the cam_topk kernel
    cam_merge: str = "global"     # global: plain top-k over the full cache
                                  # (paper-naive; all-gathers a sharded
                                  # cache).  hierarchical: the paper's
                                  # partition-and-merge — local top-k per
                                  # seq shard + comparator-style global
                                  # merge of candidates (shard_map)

    # --- numerics / runtime -------------------------------------------------
    dtype: str = "bfloat16"
    cache_dtype: str = "bfloat16"   # KV/conv cache storage dtype
    attn_impl: str = "flash"        # flash (chunked scans) | naive (S^2
                                    # einsum; identical FLOPs — used by the
                                    # dry-run cost probes, where scan bodies
                                    # are counted once by cost_analysis)
    moe_capacity_factor: float = 1.25  # EP/a2a capacity slack (overflow
                                       # assignments are dropped)
    moe_probe_balanced: bool = False  # probe-only: balanced grouped GEMM
                                      # (batched einsum) instead of
                                      # ragged_dot, whose XLA cost model
                                      # counts dense-over-all-groups
    remat: bool = True
    scan_layers: bool = True

    # ---------------------------------------------------------------- props
    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        if self.n_heads:
            return self.d_model // self.n_heads
        return 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_headdim else 0

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 for clean model-axis sharding."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def is_attention_free(self) -> bool:
        return self.attention == "none"

    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks), for 6ND math."""
        d, L = self.d_model, self.n_layers
        p = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        if self.attention == "mla":
            qk_head = self.qk_nope_dim + self.qk_rope_dim
            attn = (d * self.q_lora_rank
                    + self.q_lora_rank * self.n_heads * qk_head
                    + d * (self.kv_lora_rank + self.qk_rope_dim)
                    + self.kv_lora_rank * self.n_heads
                    * (self.qk_nope_dim + self.v_head_dim)
                    + self.n_heads * self.v_head_dim * d)
        elif self.attention == "gqa":
            attn = (d * self.n_heads * self.head_dim          # q
                    + 2 * d * self.n_kv_heads * self.head_dim  # kv
                    + self.n_heads * self.head_dim * d)        # o
        else:
            attn = 0
        if self.n_experts:
            ff_each = 3 * d * self.moe_d_ff
            ff = ff_each * (self.n_experts
                            + 2 * self.n_shared_experts)  # shared are 2x wide
            ff += d * self.n_experts                      # router
        elif self.d_ff:
            ff = (2 if self.mlp_type == "gelu" else 3) * d * self.d_ff
        else:
            ff = 0
        ssm = 0
        if self.ssm_state:
            di, ns = self.d_inner, self.ssm_state
            ssm = (d * (2 * di + 2 * self.ssm_groups * ns + self.ssm_heads)
                   + di * d                       # out proj
                   + self.ssm_conv * (di + 2 * self.ssm_groups * ns)
                   + 3 * self.ssm_heads)          # A, D, dt_bias
        per_layer = attn + ff + ssm
        if self.family == "hybrid":
            # shared attention+mlp block counted once (weight sharing)
            shared = (4 * d * self.n_heads * self.head_dim
                      + 3 * d * self.d_ff)
            return p + L * ssm + shared
        return p + L * per_layer

    def active_params(self) -> int:
        """Active (per-token) params for MoE 6·N_active·D accounting."""
        if not self.n_experts:
            return self.n_params()
        full = self.n_params()
        d = self.d_model
        routed_all = 3 * d * self.moe_d_ff * self.n_experts * self.n_layers
        routed_active = (3 * d * self.moe_d_ff * self.moe_top_k
                         * self.n_layers)
        return full - routed_all + routed_active

    # ----------------------------------------------------------------- etc
    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-reduced",
            n_layers=2,
            d_model=64,
            d_ff=128 if self.d_ff else 0,
            vocab_size=512,
            rope_theta=self.rope_theta,
        )
        if self.attention == "gqa":
            kw.update(n_heads=4, n_kv_heads=max(1, 4 * self.n_kv_heads
                                                // max(1, self.n_heads)),
                      d_head=16)
        elif self.attention == "mla":
            kw.update(n_heads=4, n_kv_heads=4, q_lora_rank=32,
                      kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
                      v_head_dim=16, d_head=16)
        else:
            kw.update(n_heads=0, n_kv_heads=0)
        if self.n_experts:
            kw.update(n_experts=8, n_shared_experts=self.n_shared_experts,
                      moe_top_k=min(2, self.moe_top_k), moe_d_ff=32, d_ff=0)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_headdim=16, ssm_expand=2,
                      ssm_chunk=32)
        if self.hybrid_attn_every:
            kw.update(hybrid_attn_every=2, n_heads=4, n_kv_heads=4, d_head=16,
                      d_ff=128)
        return dataclasses.replace(self, **kw)
