"""Architecture registry: ``get_config(arch_id)`` / ``--arch <id>``.

One module per assigned architecture (exact published config), plus the
paper's own validation applications (MANN / HDC / DRL CAM setups live in
repro.core configs, not here — these are the LM backbones).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from .base import SHAPE_SPECS, SHAPES, ModelConfig

ARCH_IDS: List[str] = [
    "musicgen-large",
    "granite-20b",
    "qwen2-1.5b",
    "minicpm3-4b",
    "granite-8b",
    "deepseek-moe-16b",
    "moonshot-v1-16b-a3b",
    "chameleon-34b",
    "mamba2-2.7b",
    "zamba2-7b",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = ["ModelConfig", "SHAPES", "SHAPE_SPECS", "ARCH_IDS", "get_config",
           "all_configs"]
