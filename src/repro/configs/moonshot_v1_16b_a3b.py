"""moonshot-v1-16b-a3b [moe]: kimi/moonlight, 64 routed top-6 experts.

48L d_model=2048 16H (kv=16) per-expert d_ff=1408 vocab=163840
[hf:moonshotai/Moonlight-16B-A3B]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,
    moe_d_ff=1408,
    n_experts=64,
    n_shared_experts=2,
    moe_top_k=6,
    vocab_size=163840,
    cam_attention=True,
    cam_router=True,
)
