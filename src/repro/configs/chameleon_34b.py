"""chameleon-34b [vlm]: early-fusion mixed-modal transformer.

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536
[arXiv:2405.09818; unverified]

The VQ image tokenizer is a STUB: image patches arrive pre-tokenized as ids
in the unified 65536 vocab (input_mode='tokens'; see DESIGN.md §3).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    cam_attention=True,
)
